"""The SSD-resident index image: page store + record formats.

Two physical formats, matching the paper's comparison setup (§5.2):

  * ``VeloIndex``  — compressed slotted layout: per-record payload is
        [ext_code d/2 B][lo f32][step f32][adj_len u16][compressed adjacency]
    packed by the affinity placement (repro.core.placement).
  * ``FixedIndex`` — DiskANN-style layout: fixed-size records
        [vector d*4 B][degree u32][neighbor ids R*4 B]
    packed sequentially (DiskANN) or block-shuffled (Starling).

Both keep the level-1 RaBitQ artifacts resident (the paper standardizes RaBitQ
in-memory compression across all compared systems).
"""

from __future__ import annotations

import dataclasses
import struct

import numpy as np

from repro.core import codec as codec_mod
from repro.core import placement as placement_mod
from repro.core.pages import PAGE_SIZE, page_lookup, page_records
from repro.core.quant import QuantizedBase, RabitQuantizer
from repro.core.vamana import VamanaGraph


@dataclasses.dataclass
class DecodedRecord:
    vid: int
    adjacency: np.ndarray        # (deg,) int64
    # exactly one of the two payload kinds is set:
    ext_payload: bytes | None = None    # velo: 4-bit code + lo/step
    vector: np.ndarray | None = None    # diskann: full fp32 vector

    def nbytes(self) -> int:
        b = self.adjacency.nbytes + 16
        if self.ext_payload is not None:
            b += len(self.ext_payload)
        if self.vector is not None:
            b += self.vector.nbytes
        return b


class PageStore:
    """The simulated SSD: a flat array of pages. Reads are free here — latency
    is charged by the discrete-event simulator, not by this object."""

    def __init__(self, pages: list[bytes], page_size: int):
        self.pages = pages
        self.page_size = page_size

    def read_page(self, pid: int) -> bytes:
        return self.pages[pid]

    @property
    def n_pages(self) -> int:
        return len(self.pages)

    def disk_bytes(self) -> int:
        return len(self.pages) * self.page_size

    def shard_bytes(self, page_shard: "np.ndarray") -> "np.ndarray":
        """Per-shard disk footprint under a page->shard assignment
        (core.sharding): how evenly the scatter-gather plane splits the index
        image across the engine shards.  The balance diagnostic the sharded
        benchmark reports alongside scaling efficiency."""
        assert len(page_shard) == len(self.pages)
        n_shards = int(page_shard.max()) + 1 if len(page_shard) else 0
        counts = np.bincount(
            np.asarray(page_shard, dtype=np.int64), minlength=n_shards
        )
        return counts * self.page_size


# ------------------------------------------------------------------ VeloIndex


class VeloIndex:
    """Compressed slotted index with affinity co-placement."""

    def __init__(
        self,
        base: np.ndarray,
        graph: VamanaGraph,
        qb: QuantizedBase,
        adj_codec: str = "pef",
        page_size: int = PAGE_SIZE,
        tau_scale: float = 1.0,   # 0 disables co-placement (tau=0 in Fig. 13)
        affine_cap: int | None = None,
    ):
        self.n, self.dim = base.shape
        self.graph = graph
        self.qb = qb
        self.adj_codec = adj_codec
        self.page_size = page_size

        self._payload_cache: dict[int, bytes] = {}

        def payload_fn(vid: int) -> bytes:
            if vid not in self._payload_cache:
                adj = np.sort(graph.neighbors(vid).astype(np.uint32))
                enc = codec_mod.encode_adjacency(adj, adj_codec)
                self._payload_cache[vid] = (
                    qb.record_payload(vid) + struct.pack("<H", len(enc)) + enc
                )
            return self._payload_cache[vid]

        if affine_cap is None and self.n:
            # paper §3.4: "We set the affinity bound k relative to page
            # capacity to prevent affinity groups from spanning multiple
            # pages." — estimate records/page from a payload sample.
            sample = [len(payload_fn(v)) + 9 for v in range(0, self.n, max(1, self.n // 64))]
            per_page = max(2, (page_size - 6) // max(1, int(np.mean(sample))))
            affine_cap = per_page - 1
        affinity = graph.affinity_ids(tau_scale=tau_scale, cap=affine_cap)
        self.layout = placement_mod.layout_affinity(
            payload_fn, self.n, affinity, page_size
        )
        self.store = PageStore(self.layout.pages, page_size)
        self._payload_cache.clear()

    # -- record access -------------------------------------------------------

    def page_of(self, vid: int) -> int:
        return int(self.layout.vid_to_page[vid])

    def color_of(self, vid: int) -> int:
        return int(self.layout.colors[vid])

    def decode_record(self, vid: int, page: bytes) -> DecodedRecord:
        hit = page_lookup(page, vid)
        assert hit is not None, f"vid {vid} not on its mapped page"
        _, payload = hit
        return self._decode_payload(vid, payload)

    def _decode_payload(self, vid: int, payload: bytes) -> DecodedRecord:
        ext_len = (self.dim // 2 if self.qb.ext_bits == 4 else self.dim) + 8
        ext = payload[:ext_len]
        (adj_len,) = struct.unpack_from("<H", payload, ext_len)
        adj = codec_mod.decode_adjacency(
            payload[ext_len + 2 : ext_len + 2 + adj_len], self.adj_codec
        )
        return DecodedRecord(vid=vid, adjacency=adj.astype(np.int64), ext_payload=ext)

    def co_resident_records(self, vid: int, page: bytes) -> list[DecodedRecord]:
        """Paper §3.4: 'Upon accessing any record with a non-zero Color tag, all
        co-tagged records on the page are proactively fetched into the buffer
        pool.'"""
        color = self.color_of(vid)
        if color == 0:
            return []
        out = []
        for slot, payload in page_records(page):
            if slot.color == color and slot.vid != vid:
                out.append(self._decode_payload(slot.vid, payload))
        return out

    def refine_dist2(self, pq, rec: DecodedRecord) -> float:
        return RabitQuantizer.refine_dist2_from_payload(self.qb, pq, rec.ext_payload)

    # -- batch access (the distance plane's record-group path) ---------------

    def record_matrix(
        self, recs: list[DecodedRecord]
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Stack fetched records' level-2 payloads into batch-decodable arrays:
        packed codes (m, d/2 or d) uint8 + per-row lo/step (m,) float32."""
        ncode = self.dim // 2 if self.qb.ext_bits == 4 else self.dim
        buf = np.frombuffer(
            b"".join(r.ext_payload for r in recs), dtype=np.uint8
        ).reshape(len(recs), ncode + 8)
        codes = buf[:, :ncode]
        tail = np.ascontiguousarray(buf[:, ncode:]).view(np.float32)  # (m, 2)
        return codes, tail[:, 0].copy(), tail[:, 1].copy()

    def refine_records(self, engine, pq, recs: list[DecodedRecord]) -> np.ndarray:
        """Level-2 refinement of a fetched record group in one engine call."""
        if not recs:
            return np.empty(0, dtype=np.float32)
        codes, lo, step = self.record_matrix(recs)
        return engine.refine(self.qb, pq, codes, lo, step)

    def refine_payload(self, recs: list[DecodedRecord], resident: bool = True):
        """(kind, payload) of the ScoreRequest refining this record group:
        quantized records refine on the extended-code path.  The resident
        wire format carries only the vertex ids — the engine gathers the
        rows from its registered level-2 table (on-device for pallas);
        ``resident=False`` materializes the (codes, lo, step) matrices from
        the fetched payload bytes (the host-gather parity path).  The two
        are bitwise interchangeable: tests assert the on-disk payloads
        round-trip to exactly the build-time code tables."""
        if resident:
            return "refine", np.asarray([r.vid for r in recs], dtype=np.int64)
        return "refine", self.record_matrix(recs)

    # -- accounting (Table 3) --------------------------------------------------

    def disk_bytes(self) -> int:
        return self.store.disk_bytes()

    def resident_bytes(self) -> int:
        return self.qb.resident_bytes() + self.layout.vid_to_page.nbytes + self.layout.colors.nbytes


# ----------------------------------------------------------------- FixedIndex


class FixedIndex:
    """DiskANN-style fixed-size-record index (also Starling's when shuffled)."""

    def __init__(
        self,
        base: np.ndarray,
        graph: VamanaGraph,
        qb: QuantizedBase,
        page_size: int = PAGE_SIZE,
        shuffle: bool = False,
    ):
        self.n, self.dim = base.shape
        self.graph = graph
        self.qb = qb
        self.page_size = page_size
        self.R = graph.R
        self.record_size = self.dim * 4 + 4 + self.R * 4

        self.per_page = max(1, page_size // self.record_size)

        if shuffle:
            order = self._bfs_order(graph)
        else:
            order = np.arange(self.n, dtype=np.int64)

        self.vid_to_page = np.empty(self.n, dtype=np.int32)
        self.vid_to_slot = np.empty(self.n, dtype=np.int32)
        pages: list[bytes] = []
        buf = bytearray()
        count = 0
        for vid in order:
            vid = int(vid)
            self.vid_to_page[vid] = len(pages)
            self.vid_to_slot[vid] = count
            vec = base[vid].astype(np.float32).tobytes()
            adj = graph.neighbors(vid).astype(np.int32)
            padded = np.full(self.R, -1, dtype=np.int32)
            padded[: len(adj)] = adj
            buf += vec + struct.pack("<i", len(adj)) + padded.tobytes()
            count += 1
            if count == self.per_page:
                buf += b"\x00" * (page_size - len(buf))
                pages.append(bytes(buf))
                buf = bytearray()
                count = 0
        if count:
            buf += b"\x00" * ((-len(buf)) % page_size)
            pages.append(bytes(buf))
        self.store = PageStore(pages, page_size)
        # record ids resident in each page (for Starling block search)
        self.page_members: list[list[int]] = [[] for _ in pages]
        for vid in range(self.n):
            self.page_members[self.vid_to_page[vid]].append(vid)

    @staticmethod
    def _bfs_order(graph: VamanaGraph) -> np.ndarray:
        from collections import deque

        n = graph.n
        seen = np.zeros(n, dtype=bool)
        order: list[int] = []
        for s in range(n):
            if seen[s]:
                continue
            dq = deque([s])
            seen[s] = True
            while dq:
                v = dq.popleft()
                order.append(v)
                for u in graph.neighbors(v):
                    u = int(u)
                    if not seen[u]:
                        seen[u] = True
                        dq.append(u)
        return np.asarray(order, dtype=np.int64)

    def page_of(self, vid: int) -> int:
        return int(self.vid_to_page[vid])

    def color_of(self, vid: int) -> int:
        return 0

    def decode_record(self, vid: int, page: bytes) -> DecodedRecord:
        slot = int(self.vid_to_slot[vid])
        off = slot * self.record_size
        vec = np.frombuffer(page, dtype=np.float32, count=self.dim, offset=off)
        (deg,) = struct.unpack_from("<i", page, off + self.dim * 4)
        adj = np.frombuffer(
            page, dtype=np.int32, count=self.R, offset=off + self.dim * 4 + 4
        )[:deg]
        return DecodedRecord(vid=vid, adjacency=adj.astype(np.int64), vector=vec)

    def co_resident_records(self, vid: int, page: bytes) -> list[DecodedRecord]:
        return []

    def page_record_ids(self, pid: int) -> list[int]:
        return self.page_members[pid]

    def refine_dist2(self, pq, rec: DecodedRecord) -> float:
        diff = rec.vector.astype(np.float32) - pq.q_orig
        return float(diff @ diff)

    # -- batch access (the distance plane's record-group path) ---------------

    def record_matrix(self, recs: list[DecodedRecord]) -> np.ndarray:
        """Stack fetched records' fp32 vectors into one (m, d) matrix."""
        return np.stack([r.vector for r in recs]).astype(np.float32, copy=False)

    def refine_records(self, engine, pq, recs: list[DecodedRecord]) -> np.ndarray:
        """Exact fp32 refinement of a fetched record group in one engine call."""
        if not recs:
            return np.empty(0, dtype=np.float32)
        return engine.refine_full(pq.q_orig, self.record_matrix(recs))

    def refine_payload(self, recs: list[DecodedRecord], resident: bool = True):
        """(kind, payload) of the ScoreRequest refining this record group:
        DiskANN-style records carry full fp32 vectors (nothing quantized is
        resident, so ``resident`` does not apply)."""
        return "full", self.record_matrix(recs)

    def disk_bytes(self) -> int:
        return self.store.disk_bytes()

    def resident_bytes(self) -> int:
        return self.qb.resident_bytes() + self.vid_to_page.nbytes + self.vid_to_slot.nbytes
