"""HBM record-cache tier: device-resident hot records above the host pool.

The paper keeps hot records close to the compute while the cold tail drains
through the async buffer pool; NDSEARCH (PAPERS.md) makes the same argument
from the hardware side — move distance work to where the data lives instead
of shipping data to the compute.  This module wires the two existing halves
together into a real second cache tier:

  * ``repro.velo.device_cache.DeviceRecordCache`` supplies the slot state —
    record-map indirection, vectorized clock sweep, LOCKED/OCCUPIED/MARKED —
    as the host mirror of the device arrays;
  * the PR 4 resident distance plane supplies the zero-upload gather: a
    refine request whose vids map to cache slots is served by a
    slot-indirection gather from ``cache_ext``/``cache_lo``/``cache_step``
    (``DistanceEngine.refine_slots``), never by re-uploading payload bytes.

Tier protocol (all host-driven, lockstep with the engine):

  lookup path   ``RecordAccessor`` consults the tier BEFORE the host pool:
                ``lookup(vid)`` rebuilds the full ``DecodedRecord`` (payload
                bytes bit-identical to the on-disk record, adjacency from
                ``cache_adj``) on a hit; a miss falls through to the pool and
                from there to the async LOCKED-window load protocol.
  admission     the pool's ``on_publish`` hook hands every freshly installed
                record to ``note_publish`` (warm-up: staged while the tier
                has free slots); a host-pool HIT on a non-tier-resident
                record calls ``note_hit`` (steady state: proven-hot records
                are promoted even when staging forces an eviction sweep).
  scatter       staged records are installed by ONE batched scatter at the
                next dispatch boundary (``scatter_staged``) — the
                double-buffered DMA the paper overlaps with the fused kernel
                of the concurrent step.  The engine charges
                ``max(0, CostModel.hbm_scatter_s - dispatch_s)``: only the
                part of the DMA the dispatch could not hide.

With the tier disabled nothing here is constructed and every caller takes
its original code path — the bitwise-parity contract tests pin down.
"""

from __future__ import annotations

import functools

import numpy as np

from repro.core.quant import CacheSlotView, QuantizedBase
from repro.core.store import DecodedRecord
from repro.velo.device_cache import (
    DeviceRecordCache,
    FREE,
    LOCKED,
    MARKED,
    OCCUPIED,
)

_SCATTER_BUCKET = 64


@functools.lru_cache(maxsize=1)
def _scatter_fn():
    """Jitted functional scatter installing staged rows into the device
    mirror of the slot arrays (the DMA the simulator charges hbm_scatter_s
    for).  Rows are bucket-padded by the caller, so jit sees few shapes;
    padding repeats row 0, which makes the duplicate writes idempotent."""
    import jax

    @jax.jit
    def scatter(ext, lo, step, slots, ext_rows, lo_rows, step_rows):
        return (
            ext.at[slots].set(ext_rows),
            lo.at[slots].set(lo_rows),
            step.at[slots].set(step_rows),
        )

    return scatter


def _pad_to_bucket(k: int, bucket: int = _SCATTER_BUCKET) -> int:
    return max(bucket, ((k + bucket - 1) // bucket) * bucket)


class HbmTier:
    """The engine-facing handle on one ``DeviceRecordCache``.

    Vid namespace: whatever the paired ``RecordBufferPool`` uses — local vids
    for a single system, global (base-shifted) vids on the serving plane's
    shared pool.  ``HbmView`` translates a tenant's local vids into this
    namespace.
    """

    def __init__(self, qb: QuantizedBase, vid_to_page: np.ndarray,
                 n_slots: int, R: int):
        dim = qb.dim
        code_cols = qb.ext_codes.shape[1]
        self.qb = qb
        self.cache = DeviceRecordCache.create(
            n_slots, np.asarray(vid_to_page), dim=dim, R=R,
            code_cols=code_cols,
        )
        self.view = CacheSlotView(
            qb=qb,
            ext=self.cache.cache_ext,
            lo=self.cache.cache_lo,
            step=self.cache.cache_step,
        )
        self._ncode = code_cols
        self._R = R
        self.scatters = 0
        # records parsed and waiting for the next dispatch-boundary scatter
        self._staged: list[tuple[int, np.ndarray, float, float, np.ndarray]] = []
        self._staged_set: set[int] = set()
        self._dev = None  # lazy device mirror of (ext, lo, step)
        # host-pool hit counts since last staging; once the tier is full a
        # record must prove itself hot (promote_after pool hits) before its
        # promotion may evict an installed slot — single touches never churn
        self.promote_after = 4
        self._hot_counts: dict[int, int] = {}

    # ------------------------------------------------------------- residency

    def ready(self, vid: int) -> bool:
        """The record can be served from a slot right now (installed, not in
        a scatter's LOCKED window) — the tier analogue of peek_present."""
        slot = int(self.cache.record_map[vid])
        return slot >= 0 and self.cache.slot_state[slot] != LOCKED

    def lookup(self, vid: int, out_vid: int | None = None) -> DecodedRecord | None:
        """Serve a full record from its cache slot, or None.

        Rebuilds the exact on-disk form: payload bytes are codes + f32 lo +
        f32 step (bit-identical to ``QuantizedBase.record_payload`` — the
        roundtrip tests pin this), adjacency is the slot's ``cache_adj`` row
        with the -1 padding stripped.  Counts a tier hit/miss and gives
        MARKED slots their second chance, mirroring the pool's lookup.
        ``out_vid`` sets the vid on the rebuilt record (a serving-plane view
        passes the tenant-local vid while addressing by global vid)."""
        slot = int(self.cache.record_map[vid])
        if slot < 0 or self.cache.slot_state[slot] == LOCKED:
            self.cache.misses += 1
            return None
        if self.cache.slot_state[slot] == MARKED:
            self.cache.slot_state[slot] = OCCUPIED  # second chance
        self.cache.hits += 1
        codes = self.cache.cache_ext[slot]
        payload = (
            codes.tobytes()
            + np.float32(self.cache.cache_lo[slot]).tobytes()
            + np.float32(self.cache.cache_step[slot]).tobytes()
        )
        row = self.cache.cache_adj[slot]
        adj = row[row >= 0].astype(np.int64)
        return DecodedRecord(
            vid=vid if out_vid is None else out_vid,
            adjacency=adj,
            ext_payload=payload,
        )

    def peek_split(
        self, ids: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray] | None:
        """Partition a refine id vector into (hit_mask, slot indices of the
        hits) for the flush-time slot gather.  NO hit/miss counting — these
        records were already counted when the searcher fetched them; this is
        the dispatch plane re-resolving residency, not a new access.  MARKED
        slots still get their second chance (a gather is a touch).  Returns
        None when nothing is resident."""
        slots = self.cache.record_map[ids]
        mask = slots >= 0
        if mask.any():
            hit_slots = slots[mask]
            locked = self.cache.slot_state[hit_slots] == LOCKED
            if locked.any():
                keep = np.nonzero(mask)[0][locked]
                mask[keep] = False
                hit_slots = slots[mask]
            if not mask.any():
                return None
            marked = self.cache.slot_state[hit_slots] == MARKED
            self.cache.slot_state[hit_slots[marked]] = OCCUPIED
            return mask, hit_slots.astype(np.int64)
        return None

    def covers(self, qb) -> bool:
        return qb is self.qb

    # ------------------------------------------------------------- admission

    def _free_headroom(self) -> int:
        used = int((self.cache.slot_state != FREE).sum())
        return self.cache.n_slots - used - len(self._staged)

    def _stage(self, vid: int, rec) -> bool:
        if (
            vid in self._staged_set
            or self.cache.record_map[vid] >= 0
            or getattr(rec, "ext_payload", None) is None
            or len(rec.adjacency) > self._R
        ):
            return False
        payload = rec.ext_payload
        codes = np.frombuffer(payload[: self._ncode], dtype=np.uint8)
        lo = float(np.frombuffer(payload[self._ncode:self._ncode + 4],
                                 dtype=np.float32)[0])
        step = float(np.frombuffer(payload[self._ncode + 4:self._ncode + 8],
                                   dtype=np.float32)[0])
        self._staged.append(
            (vid, codes, lo, step, rec.adjacency.astype(np.int32))
        )
        self._staged_set.add(vid)
        return True

    def note_publish(self, vid: int, rec) -> None:
        """Pool publication hook (the miss-list handoff): stage the freshly
        loaded record for the next scatter, but only while the tier still has
        free slots — cold-tail records never evict an installed one."""
        if self._free_headroom() > 0:
            self._stage(int(vid), rec)

    def note_hit(self, vid: int, rec) -> None:
        """Host-pool hit on a record the tier doesn't hold: promote it once
        it has proven hot.  While the tier has free slots promotion is
        immediate; once full, a record needs ``promote_after`` pool hits
        before its staging may evict an installed slot — otherwise the cold
        tail would churn the tier on every touch and the scatter DMA (plus
        the evictions) would eat the win."""
        vid = int(vid)
        if self._free_headroom() > 0:
            self._stage(vid, rec)
            return
        n = self._hot_counts.get(vid, 0) + 1
        if n >= self.promote_after:
            if self._stage(vid, rec):
                self._hot_counts.pop(vid, None)
                return
        self._hot_counts[vid] = n

    # --------------------------------------------------------------- scatter

    def scatter_staged(self) -> int:
        """Install every staged record in ONE batched admit + device scatter
        (the double-buffered DMA).  Returns the number of slots written; the
        caller charges ``hbm_scatter_s`` net of the dispatch it overlapped."""
        if not self._staged:
            return 0
        staged, self._staged = self._staged, []
        self._staged_set.clear()
        vids = np.asarray([s[0] for s in staged], dtype=np.int64)
        exts = np.stack([s[1] for s in staged])
        los = np.asarray([s[2] for s in staged], dtype=np.float32)
        steps = np.asarray([s[3] for s in staged], dtype=np.float32)
        adjs = [s[4] for s in staged]
        self.cache.admit(
            vids, exts, los, steps, adjs,
            disk_pages=self.cache.disk_pages[vids],
        )
        installed = self.cache.record_map[vids]
        written = installed[installed >= 0].astype(np.int64)
        if len(written) == 0:
            return 0
        if self._dev is not None:
            k = _pad_to_bucket(len(written))
            slots = np.zeros(k, dtype=np.int64)
            slots[: len(written)] = written
            slots[len(written):] = written[0]  # idempotent duplicate writes
            ext, lo, step = self._dev
            self._dev = _scatter_fn()(
                ext, lo, step, slots,
                self.cache.cache_ext[slots],
                self.cache.cache_lo[slots],
                self.cache.cache_step[slots],
            )
        self.scatters += 1
        return int(len(written))

    def device_arrays(self):
        """Device mirror of (cache_ext, cache_lo, cache_step) for the pallas
        slot-gather — uploaded once, then maintained functionally by the
        scatter; the per-hop path never re-uploads slot contents."""
        if self._dev is None:
            import jax

            self._dev = (
                jax.device_put(self.cache.cache_ext),
                jax.device_put(self.cache.cache_lo),
                jax.device_put(self.cache.cache_step),
            )
        return self._dev

    # --------------------------------------------------------------- gathers

    def gather(
        self, slots: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        return self.view.gather(slots)

    # ------------------------------------------------------------ accounting

    def counters(self) -> dict[str, int]:
        return {
            "hits": self.cache.hits,
            "misses": self.cache.misses,
            "evictions": self.cache.evictions,
            "scatters": self.scatters,
        }

    def nbytes(self) -> int:
        c = self.cache
        return (
            c.cache_ext.nbytes + c.cache_lo.nbytes + c.cache_step.nbytes
            + c.cache_adj.nbytes + c.slot_state.nbytes + c.slot_vid.nbytes
        )

    def hit_rate(self) -> float:
        return self.cache.hit_rate()


class HbmView:
    """A tenant's window onto a shared ``HbmTier``: translates local vids to
    the tier's global namespace and keeps per-view hit/miss counters so the
    serving plane can split tier traffic by tenant (mirror of
    ``TenantPoolView``)."""

    def __init__(self, tier: HbmTier, vid_base: int = 0):
        self.tier = tier
        self.vid_base = int(vid_base)
        self.hits = 0
        self.misses = 0

    def ready(self, vid: int) -> bool:
        return self.tier.ready(vid + self.vid_base)

    def lookup(self, vid: int) -> DecodedRecord | None:
        rec = self.tier.lookup(vid + self.vid_base, out_vid=vid)
        if rec is None:
            self.misses += 1
        else:
            self.hits += 1
        return rec

    def note_hit(self, vid: int, rec) -> None:
        self.tier.note_hit(vid + self.vid_base, rec)
