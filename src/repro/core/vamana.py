"""Vamana proximity-graph construction with fused affinity identification.

Implements DiskANN's Vamana build [23] with the paper's Algorithm 1 fused in:
while each vertex's greedy-search candidate set is in hand (already computed
for neighbor selection), filter it for affine vertices (d <= tau, up to k) at
"near-zero overhead" — no extra pass over the data, no O(n^2) reordering.

The build is *batched*: vertices are inserted in vectorized batches (greedy
searches run lockstep across the batch), which is also how ParlayANN-style
parallel builders work, and incidentally mirrors this repo's device-plane
batched search.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class VamanaGraph:
    adjacency: np.ndarray      # (n, R) int32, -1 padded, sorted ascending per row
    degrees: np.ndarray        # (n,) int32
    medoid: int
    R: int
    # Alg. 1's S: p -> [(affine vid, d2), ...] nearest-first.  Distances are
    # retained so placement can re-filter for any tau' <= tau_collect without
    # rebuilding the graph (used by the Fig. 13 tau sweep).
    affinity: dict[int, list[tuple[int, float]]]
    tau: float

    def affinity_ids(self, tau_scale: float = 1.0, cap: int | None = None) -> dict[int, list[int]]:
        """Filter the stored affinity candidates down to d <= tau_scale * tau."""
        if tau_scale <= 0:
            return {}
        lim = (tau_scale * self.tau) ** 2
        out: dict[int, list[int]] = {}
        for p, cands in self.affinity.items():
            ids = [v for v, d2 in cands if d2 <= lim]
            if cap is not None:
                ids = ids[:cap]
            if ids:
                out[p] = ids
        return out

    @property
    def n(self) -> int:
        return self.adjacency.shape[0]

    def neighbors(self, v: int) -> np.ndarray:
        return self.adjacency[v, : self.degrees[v]]


# ------------------------------------------------------------------ utilities


def _dist2(base: np.ndarray, ids: np.ndarray, q: np.ndarray) -> np.ndarray:
    diff = base[ids] - q
    return np.einsum("ij,ij->i", diff, diff)


def find_medoid(base: np.ndarray, sample: int = 4096, seed: int = 0) -> int:
    rng = np.random.default_rng(seed)
    centroid = base.mean(axis=0)
    n = base.shape[0]
    ids = rng.choice(n, size=min(sample, n), replace=False)
    d2 = _dist2(base, ids, centroid)
    return int(ids[np.argmin(d2)])


def default_tau(base: np.ndarray, n_clusters: int = 32, iters: int = 8, seed: int = 0) -> float:
    """Paper §3.4: 'tau to the average of the 5th-percentile distance-to-centroid
    values across all clusters', clusters from the quantization stage.  We run a
    small k-means (the same clustering RaBitQ-style quantizers use)."""
    rng = np.random.default_rng(seed)
    n = base.shape[0]
    sample = base[rng.choice(n, size=min(n, 16_384), replace=False)]
    centers = sample[rng.choice(sample.shape[0], size=n_clusters, replace=False)].copy()
    for _ in range(iters):
        d2 = (
            (sample**2).sum(1)[:, None]
            - 2 * sample @ centers.T
            + (centers**2).sum(1)[None, :]
        )
        assign = d2.argmin(axis=1)
        for c in range(n_clusters):
            mask = assign == c
            if mask.any():
                centers[c] = sample[mask].mean(axis=0)
    d2 = (
        (sample**2).sum(1)[:, None]
        - 2 * sample @ centers.T
        + (centers**2).sum(1)[None, :]
    )
    assign = d2.argmin(axis=1)
    dmin = np.sqrt(np.maximum(d2[np.arange(len(sample)), assign], 0.0))
    percs = []
    for c in range(n_clusters):
        mask = assign == c
        if mask.sum() >= 5:
            percs.append(np.percentile(dmin[mask], 5.0))
    tau_centroid = float(np.mean(percs)) if percs else float(np.percentile(dmin, 5.0))

    # Adaptation: the paper's centroid-percentile heuristic can fall below the
    # typical nearest-neighbor distance (then no pair is ever 'affine' and
    # co-placement silently degenerates).  Floor tau at the median 2nd-NN
    # distance of a small sample so affinity groups are non-trivial on any
    # geometry; noted in DESIGN.md.
    sub = sample[rng.choice(sample.shape[0], size=min(1024, sample.shape[0]), replace=False)]
    dd = (
        (sub**2).sum(1)[:, None] - 2 * sub @ sub.T + (sub**2).sum(1)[None, :]
    )
    np.fill_diagonal(dd, np.inf)
    nn2 = np.sqrt(np.maximum(np.partition(dd, 1, axis=1)[:, 1], 0.0))
    tau_nn = float(np.median(nn2)) * 1.1
    return max(tau_centroid, tau_nn)


# ---------------------------------------------------------- batched greedy search


def batched_greedy_search(
    base: np.ndarray,
    adjacency: list[np.ndarray],
    entry: int,
    queries: np.ndarray,
    L: int,
    max_iters: int | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Lockstep greedy search for a batch of queries over the *current* graph.

    Returns (visited_ids, visited_d2): (B, T) arrays padded with -1/inf, in
    visit order — exactly the [V, D] of Alg. 1 line 5 that both RobustPrune and
    affinity extraction consume.
    """
    B = queries.shape[0]
    max_iters = max_iters or (4 * L)

    INF = np.float32(np.inf)
    cand_ids = np.full((B, L), -1, dtype=np.int64)
    cand_d2 = np.full((B, L), INF, dtype=np.float32)
    cand_visited = np.ones((B, L), dtype=bool)  # padding counts as visited

    diff = base[entry][None, :] - queries
    cand_ids[:, 0] = entry
    cand_d2[:, 0] = np.einsum("ij,ij->i", diff, diff)
    cand_visited[:, 0] = False

    visited_ids: list[np.ndarray] = []
    visited_d2: list[np.ndarray] = []

    for _ in range(max_iters):
        masked = np.where(cand_visited, INF, cand_d2)
        best = masked.argmin(axis=1)
        active = ~np.take_along_axis(cand_visited, best[:, None], axis=1)[:, 0]
        if not active.any():
            break
        cur = np.take_along_axis(cand_ids, best[:, None], axis=1)[:, 0]
        cur_d2 = np.take_along_axis(cand_d2, best[:, None], axis=1)[:, 0]
        np.put_along_axis(cand_visited, best[:, None], True, axis=1)

        visited_ids.append(np.where(active, cur, -1))
        visited_d2.append(np.where(active, cur_d2, INF))

        # gather neighbors of each current vertex (ragged -> padded)
        neigh_list = [adjacency[int(c)] if a else np.empty(0, np.int32) for c, a in zip(cur, active)]
        width = max((len(x) for x in neigh_list), default=0)
        if width == 0:
            continue
        neigh = np.full((B, width), -1, dtype=np.int64)
        for i, nl in enumerate(neigh_list):
            neigh[i, : len(nl)] = nl
        valid = neigh >= 0
        flat = np.where(valid, neigh, 0)
        diffs = base[flat.reshape(-1)].reshape(B, width, -1) - queries[:, None, :]
        nd2 = np.einsum("bwd,bwd->bw", diffs, diffs).astype(np.float32)
        nd2 = np.where(valid, nd2, INF)

        # merge: concat then (dedupe-by-id) then keep top-L by distance
        all_ids = np.concatenate([cand_ids, neigh], axis=1)
        all_d2 = np.concatenate([cand_d2, nd2], axis=1)
        all_vis = np.concatenate([cand_visited, ~valid], axis=1)

        # dedupe: sort by id, mark repeats as inf
        order = np.argsort(all_ids, axis=1, kind="stable")
        sid = np.take_along_axis(all_ids, order, axis=1)
        sd2 = np.take_along_axis(all_d2, order, axis=1)
        svis = np.take_along_axis(all_vis, order, axis=1)
        dup = np.zeros_like(sid, dtype=bool)
        dup[:, 1:] = sid[:, 1:] == sid[:, :-1]
        # a duplicate inherits visited-ness from its first copy (cummax over runs)
        first_vis = svis & ~dup
        # propagate visitedness forward across duplicate runs
        run_vis = np.logical_or.accumulate(
            np.where(dup, False, svis), axis=1
        )  # not exact per-run; handled below via id-keyed visited set instead
        sd2 = np.where(dup, INF, sd2)

        # keep top-L by distance
        order2 = np.argsort(sd2, axis=1, kind="stable")[:, :L]
        cand_ids = np.take_along_axis(sid, order2, axis=1)
        cand_d2 = np.take_along_axis(sd2, order2, axis=1)
        cand_visited = np.take_along_axis(svis, order2, axis=1)
        cand_visited |= cand_d2 == INF
        del run_vis, first_vis

        # mark any candidate equal to an already-visited vertex as visited
        # (duplicates across iterations): check against visit history
        if visited_ids:
            hist = np.stack(visited_ids, axis=1)  # (B, t)
            eq = cand_ids[:, :, None] == hist[:, None, :]
            cand_visited |= eq.any(axis=2)

    T = len(visited_ids)
    if T == 0:
        return np.full((B, 1), -1, np.int64), np.full((B, 1), np.inf, np.float32)
    return np.stack(visited_ids, axis=1), np.stack(visited_d2, axis=1)


# ----------------------------------------------------------------- robust prune


def robust_prune(
    p: int,
    cand_ids: np.ndarray,
    cand_d2: np.ndarray,
    base: np.ndarray,
    R: int,
    alpha: float,
) -> np.ndarray:
    """DiskANN RobustPrune: alpha-dominated candidate elimination.

    alpha * d(p*, v) <= d(p, v)  (metric)  <=>  alpha^2 * d2(p*, v) <= d2(p, v).
    """
    mask = cand_ids >= 0
    ids = cand_ids[mask].astype(np.int64)
    d2 = cand_d2[mask].astype(np.float32)
    ids, uniq = np.unique(ids, return_index=True)
    d2 = d2[uniq]
    keep = ids != p
    ids, d2 = ids[keep], d2[keep]
    order = np.argsort(d2, kind="stable")
    ids, d2 = ids[order], d2[order]

    out: list[int] = []
    alive = np.ones(len(ids), dtype=bool)
    a2 = np.float32(alpha * alpha)
    while alive.any() and len(out) < R:
        i = int(np.argmax(alive))  # first alive = nearest remaining
        p_star = int(ids[i])
        out.append(p_star)
        alive[i] = False
        rem = np.nonzero(alive)[0]
        if len(rem) == 0:
            break
        dd = base[ids[rem]] - base[p_star]
        d2_star = np.einsum("ij,ij->i", dd, dd)
        dominated = a2 * d2_star <= d2[rem]
        alive[rem[dominated]] = False
    return np.asarray(sorted(out), dtype=np.int32)


# ------------------------------------------------------------------- the build


def build_vamana(
    base: np.ndarray,
    R: int = 32,
    L: int = 64,
    alpha: float = 1.2,
    tau: float | None = None,
    affine_k: int = 8,
    batch_size: int = 256,
    seed: int = 0,
    two_pass: bool = True,
) -> VamanaGraph:
    """Algorithm 1: Vamana build + fused affine-record identification."""
    n, d = base.shape
    rng = np.random.default_rng(seed)
    if tau is None:
        tau = default_tau(base, seed=seed)
    # collect affinity candidates out to 2*tau so placement can sweep tau
    tau2_collect = np.float32((2.0 * tau) ** 2)

    # random R-regular initial graph
    adjacency: list[np.ndarray] = []
    for v in range(n):
        nb = rng.choice(n, size=min(R, n - 1), replace=False)
        nb = nb[nb != v][: R]
        adjacency.append(np.asarray(sorted(set(int(x) for x in nb)), dtype=np.int32))

    medoid = find_medoid(base, seed=seed)
    affinity: dict[int, list[tuple[int, float]]] = {}

    passes = [1.0, alpha] if two_pass else [alpha]
    for pass_idx, pass_alpha in enumerate(passes):
        order = rng.permutation(n)
        final_pass = pass_idx == len(passes) - 1
        for s in range(0, n, batch_size):
            batch = order[s : s + batch_size]
            V, D = batched_greedy_search(base, adjacency, medoid, base[batch], L)

            inbox: dict[int, list[int]] = {}
            for bi, p in enumerate(batch):
                p = int(p)
                vids, vd2 = V[bi], D[bi]
                ok = vids >= 0

                # ---- Alg. 1 lines 6-10: affinity extraction (final pass only,
                # so colors reflect the final geometry; same reuse argument)
                if final_pass:
                    aff_mask = ok & (vd2 <= tau2_collect) & (vids != p)
                    aff_ids = vids[aff_mask]
                    aff_d2 = vd2[aff_mask]
                    if len(aff_ids):
                        sel = np.argsort(aff_d2, kind="stable")[:affine_k]
                        affinity[p] = [
                            (int(i), float(dd)) for i, dd in zip(aff_ids[sel], aff_d2[sel])
                        ]

                # ---- Alg. 1 line 12: prune to out-neighbors
                cand_ids = np.concatenate([vids[ok], adjacency[p]])
                dd = base[cand_ids.astype(np.int64)] - base[p]
                cand_d2 = np.einsum("ij,ij->i", dd, dd).astype(np.float32)
                new_out = robust_prune(p, cand_ids, cand_d2, base, R, pass_alpha)
                adjacency[p] = new_out

                # ---- Alg. 1 lines 13-16: reverse edges (deferred to batch end)
                for v in new_out:
                    inbox.setdefault(int(v), []).append(p)

            for v, incoming in inbox.items():
                merged = np.unique(
                    np.concatenate([adjacency[v], np.asarray(incoming, np.int32)])
                )
                merged = merged[merged != v]
                if len(merged) > R:
                    dd = base[merged.astype(np.int64)] - base[v]
                    d2v = np.einsum("ij,ij->i", dd, dd).astype(np.float32)
                    adjacency[v] = robust_prune(v, merged, d2v, base, R, pass_alpha)
                else:
                    adjacency[v] = merged.astype(np.int32)

    adj = np.full((n, R), -1, dtype=np.int32)
    deg = np.zeros(n, dtype=np.int32)
    for v in range(n):
        a = adjacency[v][:R]
        adj[v, : len(a)] = a
        deg[v] = len(a)
    return VamanaGraph(
        adjacency=adj, degrees=deg, medoid=medoid, R=R, affinity=affinity, tau=tau
    )
