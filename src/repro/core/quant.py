"""RaBitQ-style two-level vector quantization (paper §3.3, "Compressed Vertex-Based Record").

The paper compresses each record with ExtRaBitQ [12, 13]:

  level 1 — a 1-bit-per-dimension binary code, kept RESIDENT in memory, used for
            fast approximate distances that steer the traversal;
  level 2 — a 4-bit-per-dimension extended code stored in the on-disk record,
            used for accurate refinement once the record is fetched.

We implement the practical core of RaBitQ faithfully:

  * center on the dataset centroid, apply a random orthonormal rotation P
    (distances are rotation-invariant, but sign patterns of rotated residuals
    become unbiased direction estimators);
  * level-1 code: sign bits of the rotated residual.  The RaBitQ estimator of
    the angle between query and data residual is
        <x_hat, q_hat>  ~=  <x_bar, q_hat> / <x_bar, x_hat>
    where x_bar = sign(resid)/sqrt(d) is the quantized unit vector and
    <x_bar, x_hat> is the per-record corrective factor stored at build time;
  * level-2 code: per-record uniform 4-bit scalar quantization of the rotated
    residual (the "extended" code of ExtRaBitQ), reconstructed at refine time.

The device plane re-implements both distance evaluations as Pallas kernels
(kernels/binary_ip, kernels/int4_dist); this module is their numpy oracle and
the host plane's implementation.
"""

from __future__ import annotations

import dataclasses

import numpy as np


def _random_rotation(d: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((d, d))
    q, r = np.linalg.qr(a)
    # Fix signs so the rotation is a deterministic function of the seed.
    q *= np.sign(np.diag(r))
    return q.astype(np.float32)


def pack_bits(bits: np.ndarray) -> np.ndarray:
    """(n, d) {0,1} -> (n, d/8) uint8, little-endian within each byte."""
    n, d = bits.shape
    assert d % 8 == 0, "dimension must be a multiple of 8 for bit packing"
    return np.packbits(bits.astype(np.uint8), axis=1, bitorder="little")


def unpack_bits(packed: np.ndarray, d: int) -> np.ndarray:
    return np.unpackbits(packed, axis=1, count=d, bitorder="little")


def pack_nibbles(codes: np.ndarray) -> np.ndarray:
    """(n, d) uint8 in [0,15] -> (n, d/2) uint8, low nibble = even dim."""
    n, d = codes.shape
    assert d % 2 == 0
    lo = codes[:, 0::2] & 0xF
    hi = codes[:, 1::2] & 0xF
    return (lo | (hi << 4)).astype(np.uint8)


def unpack_nibbles(packed: np.ndarray, d: int) -> np.ndarray:
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    out = np.empty((packed.shape[0], d), dtype=np.uint8)
    out[:, 0::2] = lo
    out[:, 1::2] = hi
    return out


@dataclasses.dataclass
class QuantizedBase:
    """Build-time artifacts for the whole base set."""

    centroid: np.ndarray        # (d,)
    rotation: np.ndarray        # (d, d) orthonormal
    binary_codes: np.ndarray    # (n, d/8) uint8 — RESIDENT (level 1)
    norms: np.ndarray           # (n,) float32 — ||resid||, resident metadata
    ip_bar: np.ndarray          # (n,) float32 — <x_bar, x_hat>, resident metadata
    ext_codes: np.ndarray       # (n, d/2 or d) uint8 — on-disk (level 2)
    ext_lo: np.ndarray          # (n,) float32 — per-record quant range low
    ext_step: np.ndarray        # (n,) float32 — per-record quant step
    dim: int
    ext_bits: int = 4           # paper default 4; 8 supported (ExtRaBitQ is
                                # bit-budget-parametric; see DESIGN.md)

    # ---- memory accounting (paper Table 3's "memory footprint" components) ----
    def resident_bytes(self) -> int:
        # The dense rotation matrix is an implementation convenience: production
        # RaBitQ uses a fast structured transform (randomized Hadamard, O(d)
        # parameters), so it is excluded from the footprint accounting.
        return (
            self.binary_codes.nbytes
            + self.norms.nbytes
            + self.ip_bar.nbytes
            + self.centroid.nbytes
        )

    def record_payload(self, i: int) -> bytes:
        """The level-2 part of the on-disk record for vertex i."""
        return (
            self.ext_codes[i].tobytes()
            + np.float32(self.ext_lo[i]).tobytes()
            + np.float32(self.ext_step[i]).tobytes()
        )

    def record_payload_nbytes(self) -> int:
        return self.ext_codes.shape[1] + 8

    def decode_ext(self, packed_rows: np.ndarray) -> np.ndarray:
        """(n, payload_cols) uint8 -> (n, d) float codes (no scaling applied)."""
        if self.ext_bits == 4:
            return unpack_nibbles(packed_rows, self.dim).astype(np.float32)
        return packed_rows.astype(np.float32)


class RabitQuantizer:
    """Fits the rotation and produces both code levels."""

    def __init__(self, dim: int, seed: int = 0, ext_bits: int = 4):
        assert ext_bits in (4, 8), "extended codes: 4 (paper default) or 8 bits"
        self.dim = dim
        self.seed = seed
        self.ext_bits = ext_bits
        self.levels = (1 << ext_bits) - 1

    def fit_encode(self, base: np.ndarray) -> QuantizedBase:
        n, d = base.shape
        assert d == self.dim
        centroid = base.mean(axis=0).astype(np.float32)
        rot = _random_rotation(d, self.seed)
        resid = (base - centroid) @ rot.T  # rotated residuals; L2 preserved

        norms = np.linalg.norm(resid, axis=1).astype(np.float32)
        safe = np.maximum(norms, 1e-12)
        unit = resid / safe[:, None]

        bits = (resid > 0).astype(np.uint8)
        binary_codes = pack_bits(bits)
        # <x_bar, x_hat> with x_bar = sign/sqrt(d): mean absolute coordinate * sqrt(d)
        ip_bar = (np.abs(unit).sum(axis=1) / np.sqrt(d)).astype(np.float32)

        # Extended code: per-record uniform quantizer over the full [min, max]
        # range.  (Percentile clipping was tried and measured NET HARMFUL here:
        # mixture data has heavy per-row tails, and clipped dims contribute
        # errors ~10x the rounding noise — see EXPERIMENTS.md §Paper-validation
        # notes.  ExtRaBitQ's optimized per-vector scale would recover ~1.3x,
        # not the 2.5x a Gaussian napkin-model predicts.)
        lo = resid.min(axis=1).astype(np.float32)
        hi = resid.max(axis=1).astype(np.float32)
        step = ((hi - lo) / self.levels).astype(np.float32)
        step = np.maximum(step, 1e-12)
        codes = np.clip(
            np.rint((resid - lo[:, None]) / step[:, None]), 0, self.levels
        ).astype(np.uint8)
        ext_codes = pack_nibbles(codes) if self.ext_bits == 4 else codes

        return QuantizedBase(
            centroid=centroid,
            rotation=rot,
            binary_codes=binary_codes,
            norms=norms,
            ip_bar=ip_bar,
            ext_codes=ext_codes,
            ext_lo=lo,
            ext_step=step,
            dim=d,
            ext_bits=self.ext_bits,
        )

    # ------------------------------------------------------------------ query

    @staticmethod
    def prepare_query(qb: QuantizedBase, q: np.ndarray) -> "PreparedQuery":
        qr = (q - qb.centroid) @ qb.rotation.T
        qnorm = float(np.linalg.norm(qr))
        qunit = qr / max(qnorm, 1e-12)
        return PreparedQuery(
            qr=qr.astype(np.float32),
            qnorm=qnorm,
            qunit=qunit.astype(np.float32),
            q_orig=q.astype(np.float32),
        )

    @staticmethod
    def estimate_batch(
        qb: QuantizedBase,
        pq: "PreparedQuery",
        codes: np.ndarray,
        norms: np.ndarray,
        ip_bar: np.ndarray,
    ) -> np.ndarray:
        """Level-1 estimated squared distances over a packed code matrix.

        ``codes`` is (m, d/8) uint8 — rows of ``qb.binary_codes`` (or any
        matrix in the same format); ``norms``/``ip_bar`` are the matching
        per-row resident metadata.  This is the batch primitive the
        DistanceEngine backends share with the Pallas binary_ip kernel.
        """
        d = qb.dim
        bits = unpack_bits(codes, d).astype(np.float32)
        signs = 2.0 * bits - 1.0  # {-1, +1}
        g = (signs @ pq.qunit) / np.sqrt(d)  # <x_bar, q_hat>
        est_cos = g / np.maximum(ip_bar, 1e-6)
        est_cos = np.clip(est_cos, -1.0, 1.0)
        out = pq.qnorm**2 + norms**2 - 2.0 * pq.qnorm * norms * est_cos
        return out.astype(np.float32, copy=False)

    @staticmethod
    def estimate_dist2(
        qb: QuantizedBase, pq: "PreparedQuery", ids: np.ndarray
    ) -> np.ndarray:
        """Level-1 estimated squared distances for a set of vertex ids.

        This is the in-memory distance used to steer traversal (paper §3.1
        step iii: "estimates distances to its neighbors using their quantized
        vectors").
        """
        return RabitQuantizer.estimate_batch(
            qb, pq, qb.binary_codes[ids], qb.norms[ids], qb.ip_bar[ids]
        )

    @staticmethod
    def refine_dist2_from_payload(
        qb: QuantizedBase, pq: "PreparedQuery", payload: bytes
    ) -> float:
        """Level-2 refined squared distance from an on-disk record payload."""
        d = qb.dim
        ncode = d // 2 if qb.ext_bits == 4 else d
        codes = np.frombuffer(payload[:ncode], dtype=np.uint8)[None, :]
        lo = np.frombuffer(payload[ncode : ncode + 4], dtype=np.float32)[0]
        step = np.frombuffer(payload[ncode + 4 : ncode + 8], dtype=np.float32)[0]
        rec = qb.decode_ext(codes)[0] * step + lo
        diff = pq.qr - rec
        return float(diff @ diff)

    @staticmethod
    def refine_batch(
        qb: QuantizedBase,
        pq: "PreparedQuery",
        codes: np.ndarray,
        lo: np.ndarray,
        step: np.ndarray,
    ) -> np.ndarray:
        """Level-2 refinement over a packed extended-code matrix.

        ``codes`` is (m, d/2) uint8 nibble-packed (or (m, d) for ext_bits=8);
        ``lo``/``step`` are the matching per-row dequant parameters.  This is
        the batch primitive shared with the Pallas int4_dist kernel.
        """
        rec = qb.decode_ext(codes) * step[:, None] + lo[:, None]
        diff = pq.qr[None, :] - rec
        return (diff * diff).sum(axis=1).astype(np.float32, copy=False)

    @staticmethod
    def refine_dist2(
        qb: QuantizedBase, pq: "PreparedQuery", ids: np.ndarray
    ) -> np.ndarray:
        """Vectorized level-2 refinement straight from the arrays (device-plane path)."""
        return RabitQuantizer.refine_batch(
            qb, pq, qb.ext_codes[ids], qb.ext_lo[ids], qb.ext_step[ids]
        )


@dataclasses.dataclass
class PreparedQuery:
    qr: np.ndarray     # rotated, centered query (d,)
    qnorm: float
    qunit: np.ndarray  # qr / ||qr||
    q_orig: np.ndarray  # original query (d,) — for exact fp32 refinement paths


@dataclasses.dataclass
class ResidentView:
    """Register-once host view of an index's resident code tables.

    The distance plane registers each ``QuantizedBase`` exactly once
    (``DistanceEngine.register_index``) and serves every later id-based score
    request from this handle: contiguous aliases of the level-1 binary codes /
    norms / ip_bar and the level-2 extended codes / dequant params, so the
    per-hop hot path is a single fancy-index gather per table — no repeated
    per-call re-materialization of code matrices from payload bytes.  The
    device backends wrap the same arrays as device-resident tables (uploaded
    once, gathered on-device).
    """

    qb: "QuantizedBase"          # strong ref: pins id(qb) for the registry key
    binary_codes: np.ndarray     # (n, d/8) uint8, contiguous
    norms: np.ndarray            # (n,) float32
    ip_bar: np.ndarray           # (n,) float32
    ext_codes: np.ndarray        # (n, d/2 or d) uint8, contiguous
    ext_lo: np.ndarray           # (n,) float32
    ext_step: np.ndarray         # (n,) float32

    @classmethod
    def from_qb(cls, qb: "QuantizedBase") -> "ResidentView":
        return cls(
            qb=qb,
            binary_codes=np.ascontiguousarray(qb.binary_codes),
            norms=np.ascontiguousarray(qb.norms),
            ip_bar=np.ascontiguousarray(qb.ip_bar),
            ext_codes=np.ascontiguousarray(qb.ext_codes),
            ext_lo=np.ascontiguousarray(qb.ext_lo),
            ext_step=np.ascontiguousarray(qb.ext_step),
        )

    def gather_level1(
        self, ids: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        return self.binary_codes[ids], self.norms[ids], self.ip_bar[ids]

    def gather_level2(
        self, ids: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        return self.ext_codes[ids], self.ext_lo[ids], self.ext_step[ids]

    def nbytes(self) -> int:
        return (
            self.binary_codes.nbytes + self.norms.nbytes + self.ip_bar.nbytes
            + self.ext_codes.nbytes + self.ext_lo.nbytes + self.ext_step.nbytes
        )


@dataclasses.dataclass
class CacheSlotView:
    """Slot-indexed sibling of ``ResidentView``: the HBM record-cache tier's
    level-2 code arrays, addressed by CACHE SLOT rather than vertex id.

    Where ``ResidentView`` aliases an index's full build-time tables (gathered
    by vid), this view aliases a ``DeviceRecordCache``'s ``cache_ext`` /
    ``cache_lo`` / ``cache_step`` slot arrays — the records currently resident
    in the HBM tier.  A refine request whose vids map to slots gathers rows
    from here (``refine_slots``) instead of re-uploading payload bytes; the
    slot indirection is resolved on the host (record_map lookup) and only the
    small slot-index vector crosses to the kernel.
    """

    qb: "QuantizedBase"          # the index whose records fill the slots
    ext: np.ndarray              # (S, d/2 or d) uint8 — aliases cache_ext
    lo: np.ndarray               # (S,) float32 — aliases cache_lo
    step: np.ndarray             # (S,) float32 — aliases cache_step

    def gather(
        self, slots: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        return self.ext[slots], self.lo[slots], self.step[slots]

    def nbytes(self) -> int:
        return self.ext.nbytes + self.lo.nbytes + self.step.nbytes
