"""Public wrapper for paged decode attention."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention import kernel as _k


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_attention(
    q: jnp.ndarray,             # (B, H, Dh)
    k_pages: jnp.ndarray,       # (P, page, KVH, Dh)
    v_pages: jnp.ndarray,       # (P, page, KVH, Dh)
    block_tables: jnp.ndarray,  # (B, max_pages) int32
    context_lens: jnp.ndarray,  # (B,) int32
    scale: float | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    return _k.paged_attention_pallas(
        q, k_pages, v_pages,
        block_tables.astype(jnp.int32), context_lens.astype(jnp.int32),
        scale=scale, interpret=interpret,
    )
