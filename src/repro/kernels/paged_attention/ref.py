"""Pure-jnp oracle for decode attention through a KV block table."""

from __future__ import annotations

import jax.numpy as jnp


def paged_attention_ref(
    q: jnp.ndarray,            # (B, H, Dh) one new token per sequence
    k_pages: jnp.ndarray,      # (P, page, KVH, Dh) global KV page pool
    v_pages: jnp.ndarray,      # (P, page, KVH, Dh)
    block_tables: jnp.ndarray,  # (B, max_pages) int32 page ids (record_map analogue)
    context_lens: jnp.ndarray,  # (B,) int32 tokens present per sequence
    scale: float | None = None,
) -> jnp.ndarray:
    B, H, Dh = q.shape
    P, page, KVH, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    group = H // KVH
    scale = scale if scale is not None else Dh**-0.5

    # gather each sequence's logical KV: (B, max_pages*page, KVH, Dh)
    k = k_pages[block_tables]  # (B, max_pages, page, KVH, Dh)
    v = v_pages[block_tables]
    k = k.reshape(B, max_pages * page, KVH, Dh)
    v = v.reshape(B, max_pages * page, KVH, Dh)

    kk = jnp.repeat(k, group, axis=2)  # (B, S, H, Dh)
    vv = jnp.repeat(v, group, axis=2)
    logits = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32), kk.astype(jnp.float32))
    logits *= scale
    pos = jnp.arange(max_pages * page)[None, :]
    mask = pos < context_lens[:, None]
    logits = jnp.where(mask[:, None, :], logits, -jnp.inf)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhs,bshd->bhd", p, vv.astype(jnp.float32)).astype(q.dtype)
