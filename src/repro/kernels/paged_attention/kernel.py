"""Pallas TPU kernel: decode attention through a record-level KV block table.

This is the paper's §3.2 'record mapping array' idea applied to the KV cache
(DESIGN.md §Arch-applicability): the block table is the indirection array,
KV pages are the records, and the scalar-prefetch index_map *is* the hybrid
pointer dereference — the page id is read from SMEM before the DMA for the
corresponding KV tile is issued, so the gather never materializes a dense
(B, S, H, Dh) KV in HBM.

grid = (B, H, max_pages); the page axis is innermost/sequential, carrying the
online-softmax state in VMEM scratch.  Pages beyond a sequence's context
length are masked (their DMA still runs — TPU grids are static — but a real
deployment sizes max_pages to the batch's max context, exactly like vLLM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


NEG_INF = -1e30


def _paged_kernel(
    # scalar-prefetch operands
    block_tables_ref,           # (B, max_pages) int32 in SMEM
    context_lens_ref,           # (B,) int32 in SMEM
    # array operands
    q_ref,                      # (1, 1, Dh)
    k_ref,                      # (1, page, 1, Dh) — page selected by index_map
    v_ref,
    o_ref,                      # (1, 1, Dh)
    m_scratch, l_scratch, acc_scratch,
    *, scale: float, page: int,
):
    b = pl.program_id(0)
    pi = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(pi == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q = q_ref[0, 0].astype(jnp.float32)            # (Dh,)
    k = k_ref[0, :, 0].astype(jnp.float32)         # (page, Dh)
    v = v_ref[0, :, 0].astype(jnp.float32)         # (page, Dh)

    logits = (k @ q) * scale                        # (page,)
    pos = pi * page + jax.lax.iota(jnp.int32, page)
    valid = pos < context_lens_ref[b]
    logits = jnp.where(valid, logits, NEG_INF)
    logits = logits[None, :]                        # (1, page)

    m_prev = m_scratch[...]
    l_prev = l_scratch[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1, keepdims=True))
    p = jnp.exp(logits - m_new)                     # (1, page)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_scratch[...] = acc_scratch[...] * alpha + p @ v  # (1, Dh)
    m_scratch[...] = m_new
    l_scratch[...] = l_new

    @pl.when(pi == np_ - 1)
    def _finalize():
        o_ref[0, 0] = (
            acc_scratch[...] / jnp.maximum(l_scratch[...], 1e-30)
        )[0].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "interpret")
)
def paged_attention_pallas(
    q: jnp.ndarray,             # (B, H, Dh)
    k_pages: jnp.ndarray,       # (P, page, KVH, Dh)
    v_pages: jnp.ndarray,       # (P, page, KVH, Dh)
    block_tables: jnp.ndarray,  # (B, max_pages) int32
    context_lens: jnp.ndarray,  # (B,) int32
    scale: float | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, Dh = q.shape
    P, page, KVH, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    group = H // KVH
    scale = scale if scale is not None else Dh**-0.5

    grid = (B, H, max_pages)

    def q_map(b, h, p, *_refs):
        return (b, h, 0)

    def kv_map(b, h, p, block_tables_ref, context_lens_ref):
        # THE hybrid-pointer dereference: page id out of the table in SMEM.
        return (block_tables_ref[b, p], 0, h // group, 0)

    def o_map(b, h, p, *_refs):
        return (b, h, 0)

    kernel = functools.partial(_paged_kernel, scale=scale, page=page)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, Dh), q_map),
                pl.BlockSpec((1, page, 1, Dh), kv_map),
                pl.BlockSpec((1, page, 1, Dh), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, Dh), o_map),
            scratch_shapes=[
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, Dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, Dh), q.dtype),
        interpret=interpret,
    )(block_tables, context_lens, q, k_pages, v_pages)
