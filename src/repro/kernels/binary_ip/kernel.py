"""Pallas TPU kernel: query x packed-1-bit-code inner products as a sign GEMM.

Hardware adaptation (DESIGN.md §2): on CPUs RaBitQ's level-1 distance is a
popcount-Hamming loop; the TPU has no popcount but has a 128x128 systolic MXU.
We therefore unpack the bit codes to {-1,+1} lanes *inside VMEM* and issue a
dense GEMM — arithmetic intensity d/8 bytes -> 2d flops per code row makes
this compute-bound on the MXU for d >= 128, which is exactly where we want
the level-1 scan to sit.

Tiling: queries (BQ=128 rows) x codes (BN=256 rows) per grid cell; the full
code row (d/8 bytes, d <= 2048) lives in VMEM: VMEM use per cell =
BQ*d*4 + BN*d/8 + BQ*BN*4 ~= 1.4 MB at d=1024 — comfortably under 16 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BQ = 128
DEFAULT_BN = 256


def _binary_ip_kernel(q_ref, codes_ref, out_ref):
    q = q_ref[...]                                 # (BQ, d) f32
    c = codes_ref[...].astype(jnp.int32)           # (BN, d/8) u8 -> i32
    shifts = jnp.arange(8, dtype=jnp.int32)
    bits = (c[:, :, None] >> shifts[None, None, :]) & 1
    signs = (2 * bits - 1).reshape(c.shape[0], -1).astype(jnp.float32)  # (BN, d)
    out_ref[...] = jax.lax.dot_general(
        q.astype(jnp.float32),
        signs,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("bq", "bn", "interpret"))
def binary_ip_pallas(
    q: jnp.ndarray,        # (B, d) float
    codes: jnp.ndarray,    # (N, d/8) uint8
    bq: int = DEFAULT_BQ,
    bn: int = DEFAULT_BN,
    interpret: bool = True,
) -> jnp.ndarray:
    B, d = q.shape
    N, d8 = codes.shape
    assert d == d8 * 8
    assert B % bq == 0 and N % bn == 0, "caller (ops.py) pads to tile multiples"

    grid = (B // bq, N // bn)
    return pl.pallas_call(
        _binary_ip_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d8), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        interpret=interpret,
    )(q, codes)
