"""Pure-jnp oracle for the binary inner-product kernel."""

import jax.numpy as jnp


def unpack_signs(codes: jnp.ndarray, d: int) -> jnp.ndarray:
    """(N, d/8) uint8 (little-endian bits) -> (N, d) {-1,+1} float32."""
    c = codes.astype(jnp.int32)
    shifts = jnp.arange(8, dtype=jnp.int32)
    bits = (c[:, :, None] >> shifts[None, None, :]) & 1  # (N, d/8, 8)
    bits = bits.reshape(codes.shape[0], -1)[:, :d]
    return (2 * bits - 1).astype(jnp.float32)


def binary_ip_ref(q: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """<q_b, sign_n> for every query x code row.

    q:     (B, d) float
    codes: (N, d/8) uint8 (np.packbits bitorder='little')
    out:   (B, N) float32
    """
    d = q.shape[1]
    signs = unpack_signs(codes, d)
    return q.astype(jnp.float32) @ signs.T


def estimate_dist2_ref(
    q: jnp.ndarray,           # (B, d) rotated centered queries
    codes: jnp.ndarray,       # (N, d/8) uint8
    norms: jnp.ndarray,       # (N,)
    ip_bar: jnp.ndarray,      # (N,)
) -> jnp.ndarray:
    """Full RaBitQ level-1 distance estimate (matches core.quant numpy path)."""
    d = q.shape[1]
    qnorm = jnp.linalg.norm(q, axis=1, keepdims=True)          # (B, 1)
    qunit = q / jnp.maximum(qnorm, 1e-12)
    g = binary_ip_ref(qunit, codes) / jnp.sqrt(jnp.float32(d))  # (B, N)
    est_cos = jnp.clip(g / jnp.maximum(ip_bar[None, :], 1e-6), -1.0, 1.0)
    return qnorm**2 + norms[None, :] ** 2 - 2.0 * qnorm * norms[None, :] * est_cos
