"""Public jit'd wrapper for the binary_ip kernel: padding + estimate assembly."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.binary_ip import kernel as _k


def _pad_to(x: jnp.ndarray, axis: int, multiple: int):
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x, size
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad), size


@functools.partial(jax.jit, static_argnames=("interpret",))
def binary_ip(q: jnp.ndarray, codes: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """<q_b, sign_n> (B, N) via the Pallas kernel, any B/N (auto-padded)."""
    bq = min(_k.DEFAULT_BQ, max(8, q.shape[0]))
    bn = min(_k.DEFAULT_BN, max(8, codes.shape[0]))
    qp, B = _pad_to(q, 0, bq)
    cp, N = _pad_to(codes, 0, bn)
    out = _k.binary_ip_pallas(qp, cp, bq=bq, bn=bn, interpret=interpret)
    return out[:B, :N]


@functools.partial(jax.jit, static_argnames=("interpret",))
def estimate_dist2(
    q: jnp.ndarray,        # (B, d) rotated centered queries
    codes: jnp.ndarray,    # (N, d/8) uint8
    norms: jnp.ndarray,    # (N,)
    ip_bar: jnp.ndarray,   # (N,)
    interpret: bool = True,
) -> jnp.ndarray:
    """RaBitQ level-1 estimated squared distances (B, N).

    The GEMM runs in the kernel; the cheap per-element estimator assembly
    (norm corrections) is left to XLA fusion.
    """
    d = q.shape[1]
    qnorm = jnp.linalg.norm(q, axis=1, keepdims=True)
    qunit = q / jnp.maximum(qnorm, 1e-12)
    g = binary_ip(qunit, codes, interpret=interpret) / jnp.sqrt(jnp.float32(d))
    est_cos = jnp.clip(g / jnp.maximum(ip_bar[None, :], 1e-6), -1.0, 1.0)
    return qnorm**2 + norms[None, :] ** 2 - 2.0 * qnorm * norms[None, :] * est_cos
