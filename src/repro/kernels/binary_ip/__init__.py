from repro.kernels.binary_ip.ops import binary_ip, estimate_dist2  # noqa: F401
