"""Pallas TPU kernels for the performance-critical compute layers.

Each kernel directory contains:
  kernel.py — pl.pallas_call with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (padding, dtype dispatch, cost hints)
  ref.py    — pure-jnp oracle used by tests/test_kernels_*.py

Kernels:
  binary_ip       RaBitQ level-1: query x packed 1-bit codes as a sign GEMM
                  on the MXU (the TPU-native replacement for popcount Hamming)
  int4_dist       RaBitQ level-2: packed 4-bit dequant + squared-L2 refine
  flash_attention LM prefill attention (causal / sliding window / bidir, GQA)
  paged_attention LM decode through a record-level KV block table — the
                  paper's record_map indirection applied to the KV cache
"""
