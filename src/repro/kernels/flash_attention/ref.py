"""Pure-jnp oracle: multi-head attention with GQA + causal/sliding-window masks."""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,   # (B, H, Sq, Dh)
    k: jnp.ndarray,   # (B, KVH, Skv, Dh)
    v: jnp.ndarray,   # (B, KVH, Skv, Dh)
    causal: bool = True,
    window: int | None = None,   # sliding window size (None = full)
    scale: float | None = None,
) -> jnp.ndarray:
    B, H, Sq, Dh = q.shape
    KVH = k.shape[1]
    Skv = k.shape[2]
    assert H % KVH == 0
    group = H // KVH
    scale = scale if scale is not None else Dh**-0.5

    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    logits = logits * scale

    q_pos = jnp.arange(Sq)[:, None] + (Skv - Sq)  # align last query with last key
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vv.astype(jnp.float32)).astype(q.dtype)
