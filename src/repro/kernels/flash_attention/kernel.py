"""Pallas TPU kernel: FlashAttention-style fused attention (prefill path).

Online-softmax attention with explicit VMEM tiling:

  grid = (B, H, Sq/BQ, Skv/BK)   — the last (kv) axis is the TPU's sequential
  innermost grid dimension, so running max/denominator/accumulator live in
  VMEM scratch across kv steps and are finalized on the last step
  (FlashAttention's streaming recurrence mapped onto the Pallas grid).

Supports GQA (kv-head index derived in the BlockSpec index_map — no repeated
KV in HBM), causal masking, and sliding windows (gemma3's 5:1 local:global
pattern and jamba's long-context attention layers use the window path).

VMEM per cell: BQ*Dh + 2*BK*Dh + BQ*BK logits + BQ*Dh accumulator
~= (128*128 + 2*128*128 + 128*128 + 128*128) * 4B ~= 0.4 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_scratch, l_scratch, acc_scratch,
    *, scale: float, causal: bool, window: int | None, bq: int, bk: int,
    offset: int, kv_valid: int,
):
    """offset: key position of padded-query row 0 (so q_pos = row + offset);
    kv_valid: number of real (unpadded) keys."""
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q = q_ref[0, 0].astype(jnp.float32)        # (BQ, Dh)
    k = k_ref[0, 0].astype(jnp.float32)        # (BK, Dh)
    v = v_ref[0, 0].astype(jnp.float32)        # (BK, Dh)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                   # (BQ, BK)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + offset
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < kv_valid                     # padded keys never attended
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scratch[...]                     # (BQ, 1)
    l_prev = l_scratch[...]
    m_cur = jnp.max(logits, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new)                 # (BQ, BK)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)

    acc = acc_scratch[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scratch[...] = m_new
    l_scratch[...] = l_new
    acc_scratch[...] = acc

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scratch[...] / jnp.maximum(l_scratch[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "scale", "bq", "bk", "offset", "kv_valid", "interpret"
    ),
)
def flash_attention_pallas(
    q: jnp.ndarray,   # (B, H, Sq, Dh)
    k: jnp.ndarray,   # (B, KVH, Skv, Dh)
    v: jnp.ndarray,   # (B, KVH, Skv, Dh)
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    offset: int | None = None,     # default: right-align queries to keys
    kv_valid: int | None = None,   # default: all keys valid
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, Sq, Dh = q.shape
    KVH, Skv = k.shape[1], k.shape[2]
    assert H % KVH == 0 and Sq % bq == 0 and Skv % bk == 0
    group = H // KVH
    scale = scale if scale is not None else Dh**-0.5
    offset = offset if offset is not None else (Skv - Sq)
    kv_valid = kv_valid if kv_valid is not None else Skv

    grid = (B, H, Sq // bq, Skv // bk)
    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, offset=offset, kv_valid=kv_valid,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, Dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, Dh), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, Dh), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dh), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max m
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom l
            pltpu.VMEM((bq, Dh), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
