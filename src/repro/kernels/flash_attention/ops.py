"""Public wrapper: pads sequence lengths to tile multiples and dispatches.

The models call this for prefill; interpret=True on CPU (oracle-validated),
compiled pallas on TPU.  Padding policy:
  causal:     pad queries at the FRONT, keys at the BACK; real query i keeps
              position i + (Skv - Sq) via an explicit offset, padded keys are
              masked by kv_valid.
  non-causal: pad queries and keys at the BACK; padded key columns masked by
              kv_valid; padded query rows sliced off.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as _k


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "scale", "interpret")
)
def flash_attention(
    q: jnp.ndarray,   # (B, H, Sq, Dh)
    k: jnp.ndarray,   # (B, KVH, Skv, Dh)
    v: jnp.ndarray,   # (B, KVH, Skv, Dh)
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, Sq, Dh = q.shape
    Skv = k.shape[2]
    bq = min(_k.DEFAULT_BQ, max(8, Sq))
    bk = min(_k.DEFAULT_BK, max(8, Skv))
    pad_q = (-Sq) % bq
    pad_k = (-Skv) % bk

    if not (pad_q or pad_k):
        return _k.flash_attention_pallas(
            q, k, v, causal=causal, window=window, scale=scale,
            bq=bq, bk=bk, interpret=interpret,
        )

    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    if causal:
        qp = jnp.pad(q, ((0, 0), (0, 0), (pad_q, 0), (0, 0)))
        out = _k.flash_attention_pallas(
            qp, kp, vp, causal=True, window=window, scale=scale,
            offset=Skv - Sq - pad_q, kv_valid=Skv,
            bq=bq, bk=bk, interpret=interpret,
        )
        return out[:, :, pad_q:, :]
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    out = _k.flash_attention_pallas(
        qp, kp, vp, causal=False, window=window, scale=scale,
        offset=Skv - Sq, kv_valid=Skv,
        bq=bq, bk=bk, interpret=interpret,
    )
    return out[:, :, :Sq, :]
