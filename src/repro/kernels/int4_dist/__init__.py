from repro.kernels.int4_dist.ops import int4_dist2  # noqa: F401
