"""Pure-jnp oracle for the 4-bit dequant + squared-L2 refinement kernel."""

import jax.numpy as jnp


def unpack_nibbles(packed: jnp.ndarray, d: int) -> jnp.ndarray:
    """(N, d/2) uint8 -> (N, d) float32 codes in [0, 15] (low nibble = even dim)."""
    c = packed.astype(jnp.int32)
    lo = c & 0xF
    hi = (c >> 4) & 0xF
    inter = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)
    return inter[:, :d].astype(jnp.float32)


def int4_dist2_ref(
    q: jnp.ndarray,        # (B, d) rotated centered queries, float
    codes: jnp.ndarray,    # (N, d/2) uint8 packed nibbles
    lo: jnp.ndarray,       # (N,) per-record range low
    step: jnp.ndarray,     # (N,) per-record step
) -> jnp.ndarray:
    """||q_b - dequant(code_n)||^2 for every pair -> (B, N) float32."""
    d = q.shape[1]
    x = unpack_nibbles(codes, d) * step[:, None] + lo[:, None]  # (N, d)
    qn = (q.astype(jnp.float32) ** 2).sum(axis=1, keepdims=True)
    xn = (x**2).sum(axis=1)
    ip = q.astype(jnp.float32) @ x.T
    return qn - 2.0 * ip + xn[None, :]
