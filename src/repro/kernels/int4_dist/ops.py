"""Public jit'd wrapper for int4_dist: padding + shape normalization."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.int4_dist import kernel as _k


def _pad_rows(x: jnp.ndarray, multiple: int):
    rem = (-x.shape[0]) % multiple
    if rem == 0:
        return x
    pad = [(0, rem)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("interpret",))
def int4_dist2(
    q: jnp.ndarray,        # (B, d)
    codes: jnp.ndarray,    # (N, d/2) uint8
    lo: jnp.ndarray,       # (N,)
    step: jnp.ndarray,     # (N,)
    interpret: bool = True,
) -> jnp.ndarray:
    """Refined squared distances (B, N) from packed 4-bit codes."""
    B, N = q.shape[0], codes.shape[0]
    bq = min(_k.DEFAULT_BQ, max(8, B))
    bn = min(_k.DEFAULT_BN, max(8, N))
    qp = _pad_rows(q, bq)
    cp = _pad_rows(codes, bn)
    # pad step with 1s to keep dequant finite on padding rows
    lop = _pad_rows(lo.reshape(-1, 1), bn)
    stepp = jnp.pad(
        step.reshape(-1, 1), [(0, (-N) % bn), (0, 0)], constant_values=1.0
    )
    out = _k.int4_dist_pallas(qp, cp, lop, stepp, bq=bq, bn=bn, interpret=interpret)
    return out[:B, :N]
