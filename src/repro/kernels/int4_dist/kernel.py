"""Pallas TPU kernel: packed-4-bit dequant + squared-L2 distances.

RaBitQ level-2 refinement (paper §3.3): once a record's extended code reaches
the device tier, distances are computed against the 4-bit reconstruction.
The dequant (two nibbles per byte, per-record scale/offset) happens in VMEM
right before the MXU contraction, so HBM only ever carries d/2 bytes per
record — the same bytes the paper's SSD carries.

Tiling mirrors binary_ip: BQ x BN grid cells, full d in VMEM.
VMEM per cell at d=1024: BQ*d*4 + BN*(d/2) + BN*d*4 (dequant buffer)
+ BQ*BN*4 ~= 1.8 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BQ = 128
DEFAULT_BN = 128


def _int4_dist_kernel(q_ref, codes_ref, lo_ref, step_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)             # (BQ, d)
    c = codes_ref[...].astype(jnp.int32)           # (BN, d/2)
    lo = lo_ref[...].astype(jnp.float32)           # (BN, 1)
    step = step_ref[...].astype(jnp.float32)       # (BN, 1)

    lo4 = (c & 0xF).astype(jnp.float32)
    hi4 = ((c >> 4) & 0xF).astype(jnp.float32)
    codes = jnp.stack([lo4, hi4], axis=-1).reshape(c.shape[0], -1)  # (BN, d)
    x = codes * step + lo                          # dequant in VMEM

    qn = jnp.sum(q * q, axis=1, keepdims=True)     # (BQ, 1)
    xn = jnp.sum(x * x, axis=1)                    # (BN,)
    ip = jax.lax.dot_general(
        q, x, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                              # (BQ, BN)
    out_ref[...] = qn - 2.0 * ip + xn[None, :]


@functools.partial(jax.jit, static_argnames=("bq", "bn", "interpret"))
def int4_dist_pallas(
    q: jnp.ndarray,        # (B, d)
    codes: jnp.ndarray,    # (N, d/2) uint8
    lo: jnp.ndarray,       # (N, 1) float32
    step: jnp.ndarray,     # (N, 1) float32
    bq: int = DEFAULT_BQ,
    bn: int = DEFAULT_BN,
    interpret: bool = True,
) -> jnp.ndarray:
    B, d = q.shape
    N, d2 = codes.shape
    assert d == d2 * 2
    assert B % bq == 0 and N % bn == 0

    grid = (B // bq, N // bn)
    return pl.pallas_call(
        _int4_dist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d2), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        interpret=interpret,
    )(q, codes, lo, step)
