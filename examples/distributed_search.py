"""Distributed vector search over a virtual device mesh (device plane).

Shards a corpus over 8 virtual devices, runs the kernel-backed two-stage
compressed scan per shard under shard_map, merges with a distributed top-k —
the same program the 512-chip veloann dry-run cell lowers.

  PYTHONPATH=src python examples/distributed_search.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
import dataclasses

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dataset, vamana
from repro.core.dataset import recall_at_k
from repro.core.quant import RabitQuantizer
from repro.velo import dist_search
from repro.velo.index import DeviceIndex, from_host


def main():
    n_shards = 8
    ds = dataset.make_dataset(n=4096, d=64, n_queries=64, k=10, seed=3)
    per = ds.n // n_shards
    qb = RabitQuantizer(64, seed=0).fit_encode(ds.base)

    # per-shard local graphs (standard sharded-ANN construction)
    parts = []
    for s in range(n_shards):
        lo, hi = s * per, (s + 1) * per
        g = vamana.build_vamana(ds.base[lo:hi], R=12, L=24, seed=s, two_pass=False)
        sub = dataclasses.replace(
            qb,
            binary_codes=qb.binary_codes[lo:hi], norms=qb.norms[lo:hi],
            ip_bar=qb.ip_bar[lo:hi], ext_codes=qb.ext_codes[lo:hi],
            ext_lo=qb.ext_lo[lo:hi], ext_step=qb.ext_step[lo:hi],
        )
        parts.append(from_host(sub, g))

    def cat(field):
        return jnp.concatenate([getattr(p, field) for p in parts], axis=0)

    index = DeviceIndex(
        centroid=parts[0].centroid, rotation=parts[0].rotation,
        binary_codes=cat("binary_codes"), norms=cat("norms"),
        ip_bar=cat("ip_bar"), ext_codes=cat("ext_codes"),
        ext_lo=cat("ext_lo"), ext_step=cat("ext_step"),
        adjacency=cat("adjacency"), medoid=parts[0].medoid,
    )
    offsets = jnp.asarray(np.arange(n_shards) * per, jnp.int32)

    mesh = jax.make_mesh((n_shards,), ("shards",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    search = dist_search.make_distributed_search(
        mesh, ("shards",), mode="scan", L=64, k=10
    )
    ids, d2 = search(index, offsets, jnp.asarray(ds.queries))
    rec = recall_at_k(np.asarray(ids), ds.groundtruth, 10)
    print(f"devices={n_shards} corpus={ds.n} sharded search recall@10={rec:.3f}")
    assert rec > 0.8
    print("OK")


if __name__ == "__main__":
    main()
