"""Quickstart: build a VeloANN index, search it, check recall.

  PYTHONPATH=src python examples/quickstart.py
"""

import sys
import time

sys.path.insert(0, "src")

from repro.core import baselines, dataset, vamana
from repro.core.quant import RabitQuantizer


def main():
    t0 = time.time()
    # 1. a synthetic 5k x 64d corpus with exact ground truth
    ds = dataset.make_dataset(n=5000, d=64, n_queries=200, k=10, seed=0)

    # 2. Vamana proximity graph with fused affinity coloring (paper Alg. 1)
    graph = vamana.build_vamana(ds.base, R=24, L=48, seed=0)
    print(f"graph built: {graph.n} vertices, mean degree "
          f"{graph.degrees.mean():.1f}, {len(graph.affinity)} affinity sets "
          f"({time.time()-t0:.1f}s)")

    # 3. two-level RaBitQ-style compression (1-bit resident + 4-bit on disk)
    qb = RabitQuantizer(ds.dim, seed=0).fit_encode(ds.base)

    # 4. the full VeloANN system: compressed slotted layout + record-level
    #    buffer pool + async coroutine engine + cache-aware beam search
    cfg = baselines.SystemConfig(
        buffer_ratio=0.2, batch_size=8,
        params=baselines.SearchParams(L=48, W=4),
    )
    system = baselines.build_system("velo", ds.base, graph, qb, cfg)
    out = baselines.evaluate(system, ds)

    print(f"recall@10 = {out['recall@k']:.3f}")
    print(f"QPS       = {out['qps']:.0f} (simulated NVMe + 1 worker, B=8)")
    print(f"latency   = {out['mean_latency_ms']:.2f} ms mean, "
          f"{out['p99_latency_ms']:.2f} ms p99")
    print(f"I/O       = {out['ios_per_query']:.1f} page reads/query, "
          f"hit rate {out['hit_rate']:.2f}")
    print(f"disk      = {out['disk_bytes']/1e6:.2f} MB "
          f"(raw vectors: {ds.base.nbytes/1e6:.2f} MB)")
    assert out["recall@k"] > 0.6
    print("OK")


if __name__ == "__main__":
    main()
