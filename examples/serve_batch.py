"""End-to-end serving driver (the paper's kind of system is a serving engine):

  * builds the compressed index over a 10k corpus,
  * replays a batched query stream through the coroutine engine under three
    configurations (sync DiskANN-style baseline, async VeloANN, in-memory),
  * prints the throughput/latency/recall comparison — the local version of
    the paper's Fig. 1.

  PYTHONPATH=src python examples/serve_batch.py
"""

import sys
import time

sys.path.insert(0, "src")

from repro.core import baselines, dataset, vamana
from repro.core.quant import RabitQuantizer


def main():
    t0 = time.time()
    ds = dataset.make_dataset(n=10000, d=64, n_queries=400, k=10, seed=1)
    graph = vamana.build_vamana(ds.base, R=24, L=48, seed=1)
    qb = RabitQuantizer(ds.dim, seed=1).fit_encode(ds.base)
    print(f"index built in {time.time()-t0:.1f}s "
          f"(n={ds.n}, affinity sets={len(graph.affinity)})")

    rows = []
    for name, batch, workers in (
        ("diskann", 1, 4),      # synchronous baseline
        ("pipeann", 1, 4),      # pipelined best-first
        ("velo", 8, 4),         # coroutine-async VeloANN
        ("inmemory", 8, 4),     # the upper bound
    ):
        cfg = baselines.SystemConfig(
            buffer_ratio=0.2, batch_size=batch, n_workers=workers,
            params=baselines.SearchParams(L=48, W=4),
        )
        system = baselines.build_system(name, ds.base, graph, qb, cfg)
        out = baselines.evaluate(system, ds)
        rows.append((name, out))
        print(f"{name:10s} recall={out['recall@k']:.3f} "
              f"QPS={out['qps']:8.0f} lat={out['mean_latency_ms']:6.2f}ms "
              f"io/q={out['ios_per_query']:5.1f} hit={out['hit_rate']:.2f}")

    by = dict(rows)
    speedup = by["velo"]["qps"] / by["diskann"]["qps"]
    frac = by["velo"]["qps"] / by["inmemory"]["qps"]
    print(f"\nvelo vs diskann: {speedup:.1f}x QPS "
          f"(paper: up to 5.8x); velo vs in-memory: {frac:.2f}x "
          f"(paper: up to 0.92x at 50% buffer)")
    assert speedup > 2.0


if __name__ == "__main__":
    main()
