"""Train a reduced LM for a few hundred steps on synthetic data — shows the
training substrate end to end (data pipeline -> train step -> optimizer ->
checkpointing), with a falling loss.

  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--opt adamw8]
"""

import sys

sys.path.insert(0, "src")

from repro.launch import train as train_cli


def main():
    argv = sys.argv[1:] or []
    losses = train_cli.main(
        ["--arch", "tinyllama-1.1b", "--steps", "200", "--batch", "8",
         "--seq", "64", "--lr", "3e-3", "--log-every", "20"] + argv
    )
    import numpy as np

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    assert last < first - 0.5, f"loss did not fall: {first:.3f} -> {last:.3f}"
    print("OK: loss fell", f"{first:.3f} -> {last:.3f}")


if __name__ == "__main__":
    main()
