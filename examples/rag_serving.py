"""Retrieval-augmented serving: LM decode consulting the ANN engine.

Every decode step embeds the current hidden state (stub projection) and
queries the VeloANN device-plane index for nearest corpus entries — the
paper's system in its RAG role (its §1 motivation).  Uses a reduced
tinyllama-family model and the batched device-plane search.

  PYTHONPATH=src python examples/rag_serving.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import dataset, vamana
from repro.core.quant import RabitQuantizer
from repro.models import model as Mod
from repro.velo import batch_search
from repro.velo.index import from_host


def main():
    rng = np.random.default_rng(0)

    # --- the retrieval corpus: documents embedded in a d=64 space
    ds = dataset.make_dataset(n=3000, d=64, n_queries=10, k=5, seed=5)
    graph = vamana.build_vamana(ds.base, R=16, L=32, seed=5, two_pass=False)
    qb = RabitQuantizer(64, seed=5).fit_encode(ds.base)
    index = from_host(qb, graph)

    # --- a reduced LM (d_model=64 matches the corpus space for the stub)
    cfg = configs.get("tinyllama-1.1b", reduced=True)
    model = Mod.build(cfg)
    params = Mod.init_params(model, jax.random.key(0))

    B, S = 4, 16
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    logits, _ = jax.jit(lambda p, b: Mod.prefill(model, p, b))(params, batch)

    caches = Mod.init_decode_caches(model, B, cache_len=S + 8)
    decode = jax.jit(lambda p, c, t, pos: Mod.decode_step(model, p, c, t, pos))
    search = jax.jit(lambda q: batch_search.batch_search(index, q, L=32, k=5))

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for step in range(4):
        logits, caches = decode(params, caches, tok, jnp.int32(S + step))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        # retrieval query = current hidden proxy: embed of the sampled token
        # (stub projection into the corpus space — a real RAG system trains one)
        h = np.asarray(Mod.L.embed(tok, params["embed"]).astype(jnp.float32))
        ids, d2, _ = search(jnp.asarray(h[:, :64]))
        print(f"decode step {step}: tokens={np.asarray(tok)} "
              f"retrieved_docs={np.asarray(ids)[:, :3].tolist()}")
    print("OK: decode loop with per-step ANN retrieval")


if __name__ == "__main__":
    main()
